"""Batched multi-config sweep (ISSUE 2 tentpole): vmap-over-configs
must be a pure batching transform — every config's trajectory identical
to a sequential per-config ``fit_mapreduce`` run with the same
``SolverParams`` slice — and the per-config eq. 8 masking must stop
finished configs without disturbing the rest."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import (KernelConfig, MRSVMConfig, SVMConfig,
                        fit_mapreduce, fit_mapreduce_sweep,
                        fit_one_vs_rest_sweep, predict, predict_sweep,
                        stack_params, sweep_grid)

REPO = Path(__file__).resolve().parents[1]


def _problem(n=256, d=10, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    X = jax.random.normal(k1, (n, d))
    w = jax.random.normal(k2, (d,))
    y = jnp.sign(X @ w + 0.05)
    return X, y


def test_sweep_grid_shapes():
    cfg = SVMConfig(C=2.0, tol=1e-4)
    p = sweep_grid(cfg, C=[0.1, 1.0, 10.0], gamma=[0.5, 2.0])
    assert p.C.shape == (6,)
    for leaf in p:
        assert leaf.shape == (6,)
    # unspecified axes take the static-shell defaults
    np.testing.assert_allclose(np.asarray(p.tol), 1e-4)
    # C-major ordering (itertools.product convention)
    np.testing.assert_allclose(np.asarray(p.C),
                               [0.1, 0.1, 1.0, 1.0, 10.0, 10.0])
    np.testing.assert_allclose(np.asarray(p.gamma),
                               [0.5, 2.0, 0.5, 2.0, 0.5, 2.0])


def test_stack_params_roundtrip():
    cfgs = [SVMConfig(C=c) for c in (0.1, 1.0, 10.0)]
    p = stack_params([c.params() for c in cfgs])
    np.testing.assert_allclose(np.asarray(p.C), [0.1, 1.0, 10.0])


def test_batched_sweep_matches_sequential_linear():
    """Acceptance: ≥8 configs, batched risks/predictions ≡ sequential."""
    X, y = _problem()
    cfg = MRSVMConfig(sv_capacity=32, gamma=1e-4, max_rounds=3,
                      svm=SVMConfig(C=1.0, max_epochs=10))
    params = sweep_grid(cfg.svm, C=[0.01, 0.1, 1.0, 10.0],
                        tol=[1e-3, 1e-2])
    S = params.C.shape[0]
    assert S == 8
    res = fit_mapreduce_sweep(X, y, 4, cfg, params)
    preds = predict_sweep(res, X, cfg)
    for s in range(S):
        p_s = compat.tree_map(lambda a: a[s], params)
        seq = fit_mapreduce(X, y, 4, cfg, params=p_s)
        np.testing.assert_allclose(float(res.risks[s]), float(seq.risk),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.ws[s]), np.asarray(seq.w),
                                   rtol=1e-4, atol=1e-5)
        assert int(res.rounds[s]) == seq.rounds
        seq_pred = predict(seq, X, cfg, params=p_s)
        np.testing.assert_array_equal(np.asarray(preds[s]),
                                      np.asarray(seq_pred))


def test_batched_sweep_matches_sequential_rbf():
    """(C, kernel-scale) sweep on the Gram path — gamma is traced."""
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(0, 1, (192, 2)).astype(np.float32))
    y = jnp.sign(X[:, 0] * X[:, 1])
    cfg = MRSVMConfig(sv_capacity=32, max_rounds=2, gamma=1e-3,
                      svm=SVMConfig(C=10.0, max_epochs=10,
                                    kernel=KernelConfig("rbf", gamma=1.0)))
    params = sweep_grid(cfg.svm, C=[1.0, 10.0], gamma=[0.3, 1.0, 3.0])
    res = fit_mapreduce_sweep(X, y, 4, cfg, params)
    preds = predict_sweep(res, X, cfg)
    for s in range(params.C.shape[0]):
        p_s = compat.tree_map(lambda a: a[s], params)
        seq = fit_mapreduce(X, y, 4, cfg, params=p_s)
        np.testing.assert_allclose(float(res.risks[s]), float(seq.risk),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_array_equal(
            np.asarray(preds[s]), np.asarray(predict(seq, X, cfg,
                                                     params=p_s)))


def test_per_config_eq8_masking():
    """A huge driver γ stops every config at round 2 (eq. 8) and the
    masking records per-config round counts."""
    X, y = _problem(n=128, d=6, seed=2)
    cfg = MRSVMConfig(sv_capacity=32, gamma=1.0, max_rounds=8,
                      svm=SVMConfig(C=1.0, max_epochs=10))
    params = sweep_grid(cfg.svm, C=[0.1, 1.0, 10.0])
    res = fit_mapreduce_sweep(X, y, 4, cfg, params)
    assert (res.rounds == 2).all()


def test_mixed_convergence_does_not_disturb_active_configs():
    """Configs that converge early must freeze while the rest keep the
    exact sequential trajectory."""
    X, y = _problem(n=192, d=8, seed=3)
    # tiny C converges (risk plateaus) sooner than C=1 with tight gamma
    cfg = MRSVMConfig(sv_capacity=32, gamma=5e-3, max_rounds=6,
                      svm=SVMConfig(C=1.0, max_epochs=12))
    params = sweep_grid(cfg.svm, C=[1e-4, 1.0])
    res = fit_mapreduce_sweep(X, y, 4, cfg, params)
    for s in range(2):
        p_s = compat.tree_map(lambda a: a[s], params)
        seq = fit_mapreduce(X, y, 4, cfg, params=p_s)
        assert int(res.rounds[s]) == seq.rounds
        np.testing.assert_allclose(float(res.risks[s]), float(seq.risk),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.sv.alpha[s]),
                                   np.asarray(seq.sv.alpha),
                                   rtol=1e-4, atol=1e-5)


def test_ovr_folds_into_batch_axis():
    """k classes × S configs == one k·S-job batch."""
    rng = np.random.default_rng(1)
    y = rng.integers(-1, 2, size=240)
    X = jnp.asarray(rng.normal(0, 1, (240, 8)).astype(np.float32))
    X = X + 2.0 * jnp.asarray(y)[:, None]
    cfg = MRSVMConfig(sv_capacity=64, gamma=1e-4, max_rounds=4,
                      svm=SVMConfig(C=1.0, max_epochs=20))
    params = sweep_grid(cfg.svm, C=[1e-3, 1.0])
    ovr = fit_one_vs_rest_sweep(X, jnp.asarray(y), [-1, 0, 1], 4, cfg,
                                params)
    assert ovr.result.risks.shape == (6,)          # 2 configs × 3 classes
    preds = ovr.predict(X)
    assert preds.shape == (2, 240)
    accs = np.asarray(jnp.mean(preds == jnp.asarray(y)[None, :], axis=1))
    # the sweep-selected config is (near-)best on accuracy too
    assert accs[ovr.best] >= accs.max() - 0.05
    assert accs[ovr.best] > 0.7
    # risk ranking orders the degenerate C below the working one
    assert ovr.risks()[1] < ovr.risks()[0]


def test_pallas_gram_traced_kernel_sweep_matches_xla():
    """γ/coef0 are traced scalar operands of the Pallas Gram kernel
    (ISSUE 4 satellite): a traced rbf sweep on ``gram_impl='pallas'``
    must reproduce the XLA Gram path config-for-config — the rejection
    guard this replaces is gone."""
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.normal(0, 1, (128, 2)).astype(np.float32))
    y = jnp.sign(X[:, 0] * X[:, 1])
    kernel = KernelConfig("rbf", gamma=1.0)
    mk = lambda impl: MRSVMConfig(
        sv_capacity=32, max_rounds=2, gamma=1e-3,
        svm=SVMConfig(C=10.0, max_epochs=8, use_gram=True, gram_impl=impl,
                      kernel=kernel))
    cfg_p, cfg_x = mk("pallas"), mk("xla")
    params = sweep_grid(cfg_p.svm, C=[1.0, 10.0], gamma=[0.3, 1.0, 3.0])
    res_p = fit_mapreduce_sweep(X, y, 4, cfg_p, params)
    res_x = fit_mapreduce_sweep(X, y, 4, cfg_x, params)
    np.testing.assert_allclose(np.asarray(res_p.risks),
                               np.asarray(res_x.risks), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(res_p.sv.alpha),
                               np.asarray(res_x.sv.alpha),
                               rtol=1e-4, atol=1e-4)
    assert res_p.best == res_x.best


def test_sweep_rejects_ragged_params():
    X, y = _problem(n=64, d=4)
    cfg = MRSVMConfig(sv_capacity=16, max_rounds=1,
                      svm=SVMConfig(max_epochs=2))
    from repro.core import SolverParams
    bad = SolverParams(C=jnp.ones((3,)), tol=jnp.ones((2,)),
                       sv_threshold=jnp.ones((3,)), gamma=jnp.ones((3,)),
                       coef0=jnp.ones((3,)), max_epochs=jnp.ones((3,)))
    with pytest.raises(ValueError, match="leading"):
        fit_mapreduce_sweep(X, y, 4, cfg, bad)


_SHARDED_SWEEP_SCRIPT = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.core import (MRSVMConfig, SVMConfig, sweep_grid,
                        build_sharded_sweep_round, run_sharded_sweep,
                        fit_mapreduce_sweep)

n, d = 512, 12
X = jax.random.normal(jax.random.PRNGKey(0), (n, d))
w = jax.random.normal(jax.random.PRNGKey(1), (d,))
y = jnp.sign(X @ w)
cfg = MRSVMConfig(sv_capacity=64, gamma=1e-4, max_rounds=3,
                  svm=SVMConfig(C=1.0, max_epochs=15))
params = sweep_grid(cfg.svm, C=[0.05, 0.5, 1.0, 5.0], tol=[1e-3, 1e-2])

mesh = compat.make_mesh((8,), ("data",))
fn = build_sharded_sweep_round(mesh, ("data",), cfg, n // 8)
sh = run_sharded_sweep(fn, X, y, None, cfg, params)

fres = fit_mapreduce_sweep(X, y, 8, cfg, params)
np.testing.assert_allclose(np.asarray(sh.risks), np.asarray(fres.risks),
                           rtol=1e-4, atol=1e-5)
np.testing.assert_allclose(np.asarray(sh.ws), np.asarray(fres.ws),
                           rtol=1e-4, atol=1e-5)
np.testing.assert_array_equal(np.asarray(sh.sv.ids), np.asarray(fres.sv.ids))
np.testing.assert_array_equal(sh.rounds, fres.rounds)
assert sh.best == fres.best
print("SHARDED_SWEEP_OK")
"""


def test_sharded_sweep_matches_functional_sweep():
    """vmap-over-configs INSIDE the shard_map round body (8 devices)
    must equal the functional sweep config-for-config."""
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", _SHARDED_SWEEP_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env=subprocess_env(PYTHONPATH=str(REPO / "src")))
    assert "SHARDED_SWEEP_OK" in r.stdout, r.stdout + r.stderr


_RING_SWEEP_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses as dc
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.core import (MRSVMConfig, SVMConfig, sweep_grid, DedupChunk,
                        build_sharded_sweep_round, run_sharded_sweep,
                        fit_mapreduce_sweep)

n, d = 512, 12
X = jax.random.normal(jax.random.PRNGKey(0), (n, d))
w = jax.random.normal(jax.random.PRNGKey(1), (d,))
y = jnp.sign(X @ w)
# a driver gamma that makes configs converge at DIFFERENT rounds, so the
# dedup ring's snapshot freezing is exercised, not just the happy path
cfg_a = MRSVMConfig(sv_capacity=64, gamma=5e-3, max_rounds=6,
                    svm=SVMConfig(C=1.0, max_epochs=15))
cfg_r = dc.replace(cfg_a, shuffle_impl="ring", shuffle_wire_dtype="float32")
params = sweep_grid(cfg_a.svm, C=[1e-4, 0.5, 1.0, 5.0])

mesh = compat.make_mesh((8,), ("data",))
fa = build_sharded_sweep_round(mesh, ("data",), cfg_a, n // 8)
fr = build_sharded_sweep_round(mesh, ("data",), cfg_r, n // 8)
assert isinstance(fr.init_sv(4, d), DedupChunk)   # shared-row ring state
sa = run_sharded_sweep(fa, X, y, None, cfg_a, params)
sr = run_sharded_sweep(fr, X, y, None, cfg_r, params)

np.testing.assert_array_equal(sa.rounds, sr.rounds)
np.testing.assert_allclose(np.asarray(sa.risks), np.asarray(sr.risks),
                           rtol=1e-6)
np.testing.assert_allclose(np.asarray(sa.ws), np.asarray(sr.ws), rtol=1e-6)
np.testing.assert_array_equal(np.asarray(sa.sv.ids), np.asarray(sr.sv.ids))
np.testing.assert_allclose(np.asarray(sa.sv.x), np.asarray(sr.sv.x),
                           rtol=1e-6)
np.testing.assert_allclose(np.asarray(sa.sv.alpha), np.asarray(sr.sv.alpha),
                           rtol=1e-6)
assert sa.best == sr.best

fres = fit_mapreduce_sweep(X, y, 8, cfg_a, params)
np.testing.assert_allclose(np.asarray(sr.risks), np.asarray(fres.risks),
                           rtol=1e-4, atol=1e-5)

# per-stream (per_config_data) wave: ring ≡ allgather with distinct data
S = 4
Xs = jax.random.normal(jax.random.PRNGKey(3), (S, n, d))
ws = jax.random.normal(jax.random.PRNGKey(4), (S, d))
ys = jnp.sign(jnp.einsum("snd,sd->sn", Xs, ws))
ms = jnp.ones((S, n))
p4 = sweep_grid(cfg_a.svm, C=[0.1, 0.5, 1.0, 2.0])
fa2 = build_sharded_sweep_round(mesh, ("data",), cfg_a, n // 8,
                                per_config_data=True)
fr2 = build_sharded_sweep_round(mesh, ("data",), cfg_r, n // 8,
                                per_config_data=True)
sa2 = run_sharded_sweep(fa2, Xs, ys, ms, cfg_a, p4)
sr2 = run_sharded_sweep(fr2, Xs, ys, ms, cfg_r, p4)
np.testing.assert_allclose(np.asarray(sa2.risks), np.asarray(sr2.risks),
                           rtol=1e-6)
np.testing.assert_array_equal(np.asarray(sa2.sv.ids),
                              np.asarray(sr2.sv.ids))
np.testing.assert_allclose(np.asarray(sa2.sv.x), np.asarray(sr2.sv.x),
                           rtol=1e-6)
print("RING_SWEEP_OK")
"""


_HIER_SWEEP_SCRIPT = """
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses as dc
import jax, jax.numpy as jnp, numpy as np
from repro import compat
from repro.core import (MRSVMConfig, SVMConfig, sweep_grid, DedupChunk,
                        build_sharded_sweep_round, run_sharded_sweep,
                        fit_mapreduce_sweep, save_sweep_state,
                        restore_sweep_state)

n, d = 512, 12
X = jax.random.normal(jax.random.PRNGKey(0), (n, d))
w = jax.random.normal(jax.random.PRNGKey(1), (d,))
y = jnp.sign(X @ w)
cfg_a = MRSVMConfig(sv_capacity=64, gamma=5e-3, max_rounds=6,
                    svm=SVMConfig(C=1.0, max_epochs=15))
# 2 simulated hosts x 4 locals: the two-level schedule, f32 wire so the
# allgather run stays the strict oracle
cfg_h = dc.replace(cfg_a, shuffle_impl="hier", shuffle_wire_dtype="float32",
                   hier_num_hosts=2)
params = sweep_grid(cfg_a.svm, C=[1e-4, 0.5, 1.0, 5.0])

mesh = compat.make_mesh((8,), ("data",))
fa = build_sharded_sweep_round(mesh, ("data",), cfg_a, n // 8)
fh = build_sharded_sweep_round(mesh, ("data",), cfg_h, n // 8)
assert isinstance(fh.init_sv(4, d), DedupChunk)   # shared-row dedup state
sa = run_sharded_sweep(fa, X, y, None, cfg_a, params)
sh = run_sharded_sweep(fh, X, y, None, cfg_h, params)

np.testing.assert_array_equal(sa.rounds, sh.rounds)
np.testing.assert_allclose(np.asarray(sa.risks), np.asarray(sh.risks),
                           rtol=1e-6)
np.testing.assert_allclose(np.asarray(sa.ws), np.asarray(sh.ws), rtol=1e-6)
np.testing.assert_array_equal(np.asarray(sa.sv.ids), np.asarray(sh.sv.ids))
np.testing.assert_allclose(np.asarray(sa.sv.x), np.asarray(sh.sv.x),
                           rtol=1e-6)
np.testing.assert_allclose(np.asarray(sa.sv.alpha), np.asarray(sh.sv.alpha),
                           rtol=1e-6)
assert sa.best == sh.best

fres = fit_mapreduce_sweep(X, y, 8, cfg_a, params)
np.testing.assert_allclose(np.asarray(sh.risks), np.asarray(fres.risks),
                           rtol=1e-4, atol=1e-5)

# dedup state round-trip: the DedupChunk wire layout is a property of
# the packed wire format, not the hop schedule — a hier round state
# must survive save_sweep_state/restore_sweep_state and resume
# bit-for-bit (the mid-training recovery path of DESIGN.md §13)
mask = jnp.ones((n,))
state = fh.init_sv(4, d)
for t in range(2):
    state, risks, ws, bs = fh(X, y, mask, state, params)
ckpt_dir = tempfile.mkdtemp(prefix="hier_sweep_")
save_sweep_state(os.path.join(ckpt_dir, "sweep_1.npz"), state, step=1)
state_r = restore_sweep_state(os.path.join(ckpt_dir, "sweep_1.npz"),
                              cfg_h, 4, d, 8, n // 8)
out_r = fh(X, y, mask, state_r, params)
out_u = fh(X, y, mask, state, params)
for a, b in zip(jax.tree_util.tree_leaves(out_r),
                jax.tree_util.tree_leaves(out_u)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("HIER_SWEEP_OK")
"""


def test_hier_sweep_matches_allgather_and_functional():
    """ISSUE 10 tentpole: the two-level hier sweep transport (dedup
    wire over the hier hop schedule) must converge to the same models
    as the allgather sweep AND the functional sweep, and its DedupChunk
    round state must round-trip through save/restore_sweep_state
    bit-for-bit."""
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", _HIER_SWEEP_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env=subprocess_env(PYTHONPATH=str(REPO / "src")))
    assert "HIER_SWEEP_OK" in r.stdout, r.stdout + r.stderr


def test_ring_sweep_matches_allgather_and_functional():
    """ISSUE 4 tentpole: the ring-pipelined, cross-config-deduplicated
    sweep transport must converge to the same models as the allgather
    sweep AND the functional sweep — including when configs freeze at
    different rounds (the dedup state is snapshot-frozen, not
    per-round-frozen)."""
    from conftest import subprocess_env
    r = subprocess.run([sys.executable, "-c", _RING_SWEEP_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env=subprocess_env(PYTHONPATH=str(REPO / "src")))
    assert "RING_SWEEP_OK" in r.stdout, r.stdout + r.stderr


def test_per_config_max_epochs_cutoff():
    """SolverParams.max_epochs is a traced per-config epoch budget:
    the solver must stop at min(static bound, cutoff) and a sweep over
    cutoffs must equal per-config sequential runs (ROADMAP sweep
    follow-up)."""
    from repro.core import fit_binary
    X, y = _problem(n=96, d=6, seed=5)
    cfg = SVMConfig(C=1.0, max_epochs=20, tol=1e-9)

    m4 = fit_binary(X, y, cfg=cfg, params=cfg.params()._replace(
        max_epochs=jnp.asarray(4.0)))
    assert int(m4.epochs_run) == 4
    # the cutoff can only tighten the static bound
    m_over = fit_binary(X, y, cfg=cfg, params=cfg.params()._replace(
        max_epochs=jnp.asarray(100.0)))
    assert int(m_over.epochs_run) <= 20

    mr = MRSVMConfig(sv_capacity=32, gamma=1e-6, max_rounds=2,
                     svm=cfg)
    params = sweep_grid(cfg, max_epochs=[2, 5, 20])
    res = fit_mapreduce_sweep(X, y, 4, mr, params)
    for s in range(3):
        p_s = compat.tree_map(lambda a: a[s], params)
        seq = fit_mapreduce(X, y, 4, mr, params=p_s)
        np.testing.assert_allclose(float(res.risks[s]), float(seq.risk),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(res.ws[s]), np.asarray(seq.w),
                                   rtol=1e-4, atol=1e-5)
    # tighter epoch budgets on a tight tol leave higher risk
    r = np.asarray(res.risks)
    assert r[0] >= r[2] - 1e-5


@pytest.mark.slow
def test_launcher_sweep_mode():
    """`repro.launch.train --arch svm-tfidf --sweep S` drives the
    sharded sweep end to end and reports a selected config."""
    from conftest import subprocess_env
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "svm-tfidf",
         "--smoke", "--sweep", "4", "--rounds", "2"],
        capture_output=True, text=True, timeout=600, cwd=str(REPO),
        env=subprocess_env(
            XLA_FLAGS="--xla_force_host_platform_device_count=8",
            PYTHONPATH=str(REPO / "src")))
    assert "sweep selected C=" in r.stdout, r.stdout + r.stderr
    assert r.stdout.count("config C=") == 4
