"""End-to-end behaviour tests for the paper's system: synthetic Turkish
tweet corpus → TF×IDF → distributed MapReduce SVM → polarity tables."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (MRSVMConfig, SVMConfig, confusion_matrix,
                        fit_mapreduce, fit_one_vs_rest, predict)
from repro.text import (CorpusConfig, fit_transform, generate, vectorize)


@pytest.fixture(scope="module")
def two_class_pipeline():
    cfg = CorpusConfig(num_messages=2000, classes=(-1, 1), seed=0)
    corpus = generate(cfg)
    counts = vectorize(corpus.texts, 4096)
    X, _ = fit_transform(jnp.asarray(counts))
    y = jnp.asarray(corpus.labels, jnp.float32)
    mcfg = MRSVMConfig(sv_capacity=256, gamma=1e-4, max_rounds=4,
                       svm=SVMConfig(C=1.0, max_epochs=15))
    model = fit_mapreduce(X, y, num_partitions=8, cfg=mcfg)
    return corpus, X, y, model, mcfg


def test_two_class_accuracy_in_paper_ballpark(two_class_pipeline):
    """Paper Tablo 6 diagonal = 85.9%; our synthetic corpus with matched
    class balance should land at or above that regime (≥80%)."""
    _, X, y, model, mcfg = two_class_pipeline
    pred = predict(model, X, mcfg)
    acc = float(jnp.mean(pred == y))
    assert acc > 0.80


def test_confusion_matrix_shape_and_mass(two_class_pipeline):
    _, X, y, model, mcfg = two_class_pipeline
    pred = predict(model, X, mcfg)
    cm = confusion_matrix(y, pred, [-1, 1])
    assert cm.shape == (2, 2)
    assert abs(cm.sum() - 100.0) < 1e-3
    assert np.trace(cm) > 80.0


def test_university_polarity_ranking(two_class_pipeline):
    """Tablo 7 analogue: per-university positive-rate ranking exists and
    is non-degenerate (the corpus plants per-university skew)."""
    corpus, X, y, model, mcfg = two_class_pipeline
    pred = np.asarray(predict(model, X, mcfg))
    unis = corpus.universities
    rates = []
    for u in range(len(corpus.university_names)):
        sel = unis == u
        if sel.sum() >= 5:
            rates.append((pred[sel] > 0).mean())
    rates = np.asarray(rates)
    assert len(rates) > 50
    assert rates.std() > 0.05           # planted skew is visible


def test_three_class_model_runs():
    cfg = CorpusConfig(num_messages=1200, classes=(-1, 0, 1), seed=1)
    corpus = generate(cfg)
    X, _ = fit_transform(jnp.asarray(vectorize(corpus.texts, 4096)))
    y = jnp.asarray(corpus.labels, jnp.float32)
    mcfg = MRSVMConfig(sv_capacity=128, max_rounds=3,
                       svm=SVMConfig(C=1.0, max_epochs=15))
    ovr = fit_one_vs_rest(X, y, [-1, 0, 1], 4, mcfg)
    pred = ovr.predict(X)
    cm = confusion_matrix(y, pred, [-1, 0, 1])
    # paper Tablo 8 diagonal = 68.4%; synthetic should beat it
    assert np.trace(cm) > 68.0


def test_more_partitions_do_not_break_convergence():
    """Paper's scalability claim: accuracy holds as L grows."""
    cfg = CorpusConfig(num_messages=1600, classes=(-1, 1), seed=2)
    corpus = generate(cfg)
    X, _ = fit_transform(jnp.asarray(vectorize(corpus.texts, 2048)))
    y = jnp.asarray(corpus.labels, jnp.float32)
    accs = {}
    for L in (2, 8, 16):
        mcfg = MRSVMConfig(sv_capacity=256, gamma=1e-4, max_rounds=4,
                           svm=SVMConfig(C=1.0, max_epochs=15))
        m = fit_mapreduce(X, y, num_partitions=L, cfg=mcfg)
        accs[L] = float(jnp.mean(predict(m, X, mcfg) == y))
    assert min(accs.values()) > max(accs.values()) - 0.08, accs


def test_pipeline_with_feature_selection():
    """The paper's full pipeline order: stopwords → vector space →
    feature selection → SVM. χ² top-25% keeps paper-ballpark accuracy."""
    from repro.text import select_top_k
    cfg = CorpusConfig(num_messages=1500, classes=(-1, 1), seed=4)
    corpus = generate(cfg)
    X, _ = fit_transform(jnp.asarray(vectorize(corpus.texts, 4096)))
    y = jnp.asarray(corpus.labels, jnp.float32)
    X_sel, idx = select_top_k(X, y, [-1, 1], 1024)
    assert X_sel.shape == (1500, 1024)
    mcfg = MRSVMConfig(sv_capacity=256, gamma=1e-4, max_rounds=4,
                       svm=SVMConfig(C=1.0, max_epochs=15))
    model = fit_mapreduce(X_sel, y, 4, mcfg)
    acc = float(jnp.mean(predict(model, X_sel, mcfg) == y))
    assert acc > 0.8
