"""Unit tests: TF×IDF text pipeline (paper eq. 10-11, Tablo 4)."""
import jax.numpy as jnp
import numpy as np

from repro.text import (CorpusConfig, TURKISH_STOPWORDS,
                        chi2_scores, fit_idf, fit_transform, generate,
                        hash_token, normalize, tokenize, transform, vectorize)


def test_stopwords_are_tablo4():
    for w in ("acaba", "ama", "nasıl", "çünkü", "yetmiş", "şeyler"):
        assert w in TURKISH_STOPWORDS
    assert "üniversite" not in TURKISH_STOPWORDS


def test_tokenizer_removes_stopwords_urls_mentions():
    toks = tokenize("Ama ODTÜ çok güzel! http://t.co/x @user #kampus")
    assert "ama" not in toks and "çok" not in toks
    assert "odtü" in toks and "güzel" in toks
    assert not any(t.startswith("http") or t.startswith("@") for t in toks)


def test_turkish_lowercasing():
    assert normalize("İYİ") == "iyi"
    assert normalize("ISPARTA").startswith("ı")


def test_hashing_is_stable_across_processes():
    # crc32-based: fixed expected bucket (guards against hash() PYTHONHASHSEED)
    assert hash_token("güzel", 4096) == hash_token("güzel", 4096)
    assert hash_token("güzel", 2 ** 31) == 1489674879


def test_idf_formula_eq10():
    counts = jnp.asarray([[1.0, 0.0], [1.0, 1.0], [1.0, 0.0], [1.0, 1.0]])
    model = fit_idf(counts, smooth=False)
    # df = [4, 2], N = 4 → idf = [log(1), log(2)]
    np.testing.assert_allclose(np.asarray(model.idf),
                               [0.0, np.log(2.0)], rtol=1e-6)


def test_tfidf_transform_eq11():
    counts = jnp.asarray([[2.0, 1.0], [0.0, 3.0]])
    model = fit_idf(counts, smooth=False)
    X = transform(counts, model, l2_normalize=False)
    np.testing.assert_allclose(np.asarray(X),
                               np.asarray(counts) * np.asarray(model.idf),
                               rtol=1e-6)


def test_l2_normalization():
    X, _ = fit_transform(jnp.asarray([[3.0, 4.0], [1.0, 0.0]]))
    norms = jnp.linalg.norm(X, axis=1)
    np.testing.assert_allclose(np.asarray(norms), [1.0, 1.0], rtol=1e-5)


def test_chi2_finds_planted_features():
    rng = np.random.default_rng(0)
    n = 400
    y = jnp.asarray(rng.choice([-1, 1], n))
    noise = rng.random((n, 32)).astype(np.float32)
    planted = (np.asarray(y)[:, None] > 0) * np.ones((n, 2), np.float32)
    X = jnp.asarray(np.concatenate([planted, noise], axis=1))
    scores = chi2_scores(X, y, [-1, 1])
    top2 = set(np.argsort(np.asarray(scores))[-2:].tolist())
    assert top2 == {0, 1}


def test_corpus_respects_tablo5_proportions():
    cfg = CorpusConfig(num_messages=6000, classes=(-1, 1), seed=3)
    c = generate(cfg)
    frac_pos = float(np.mean(c.labels == 1))
    assert 0.35 < frac_pos < 0.65          # Tablo 5 is ~50/50 + entity skew
    assert len(c.university_names) == 108 + 66
    assert int(c.university_kinds.sum()) == 66   # private count


def test_corpus_signal_is_learnable():
    cfg = CorpusConfig(num_messages=1500, classes=(-1, 1), seed=0)
    c = generate(cfg)
    X = vectorize(c.texts, 2048)
    y = np.asarray(c.labels, np.float32)
    # one-feature baseline: class-conditional means differ on lexicon dims
    pos_mean = X[y > 0].mean(0)
    neg_mean = X[y < 0].mean(0)
    assert float(np.max(np.abs(pos_mean - neg_mean))) > 0.05
